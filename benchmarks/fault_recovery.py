"""Fault-recovery serving benchmarks: recovery latency + deadline attainment.

Two records over an LVRF decode engine under the supervised Runtime:

  * ``recovery_latency`` — a scripted step fault fires while junk queries
    (pinned keys, guaranteed mid-trajectory) hold the slots.  Supervision
    stamps ``fault`` / ``recovered`` / ``first_completion_after_recovery``
    on the runtime clock; the record is the fault -> first post-recovery
    completion gap (quarantine backoff + engine rebuild, including the
    rebuilt programs' recompile + replay catch-up) and the quarantine span
    alone.
  * ``deadline_attainment`` — the same workload under seeded ChaosEngine
    step-fault rates, each rate run twice: once with a TIGHT per-request
    deadline (2.5x the slowest fault-free request) and once with a budget
    that additionally absorbs one measured recovery cycle.  Misses resolve
    as structured ``DeadlineExceededError`` — never hangs, never lost
    futures.

CPU wall clock — NOT TPU-predictive.  The transferable signals are the
STRUCTURE of the recovery cost (backoff + rebuild/recompile dominate;
replay itself is ordinary serving) and the deadline tradeoff it forces: a
tight budget converts a recovery cycle into structured misses while the
runtime keeps serving, and a budget sized to cover one recovery restores
attainment.  ``run()`` feeds the shared bench.json harness; ``python -m
benchmarks.fault_recovery`` writes BENCH_faults.json at the repo root.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, write_bench
from repro import engine as eng_mod
from repro import runtime as rt
from repro.models import lvrf
from repro.runtime import faults as flt

N_GOOD, N_JUNK = 8, 4
FAST_FAILURE = rt.FailurePolicy(max_restarts=16, backoff_initial_s=0.02,
                                backoff_factor=2.0, backoff_max_s=0.1)
DEADLINE_RATES = (0.0, 0.25, 0.5)


def _problem(seed: int = 0):
    spec = eng_mod.registry.build("lvrf_rows", jax.random.PRNGKey(0))
    cfg = lvrf.LVRFConfig()
    atoms = lvrf.init_atoms(jax.random.split(jax.random.PRNGKey(0))[0], cfg)
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(0, cfg.n_values, (N_GOOD, 3)))
    good = lvrf.encode_row(atoms, vals, cfg)
    # junk queries never converge (burn to max_iters): they are the rows
    # guaranteed live when a fault lands, hence the ones replay must re-run
    junk = jnp.asarray(rng.normal(size=(N_JUNK, cfg.vsa.dim)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(11), N_GOOD + N_JUNK)
    return spec, good, junk, keys


def _fresh_engine(spec, good, keys):
    """Build + compile-warm an engine so timed regions exclude the first
    JIT of the serving programs (recovery's REBUILD recompile stays in —
    that cost is the point)."""
    e = eng_mod.Engine(spec, slots=4, sweeps_per_step=2)
    e.submit(good[0], keys=keys[:1])
    e.drain()
    e.completed.clear()
    return e


class _FailOnStep:
    """Deterministic fault wrapper: raises on scripted step indices,
    forwards everything else (same shape as the chaos-test wrapper)."""

    def __init__(self, inner, fail_steps):
        self.inner, self.fail_steps, self.steps = inner, set(fail_steps), 0

    def step(self):
        self.steps += 1
        if self.steps in self.fail_steps:
            raise flt.InjectedFault("scripted step fault")
        return self.inner.step()

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _submit_all(r, good, junk, keys, deadline_s=None):
    kw = {} if deadline_s is None else {"deadline_s": deadline_s}
    gids = [r.submit("lvrf", junk[j], keys=keys[N_GOOD + j][None], **kw)
            for j in range(N_JUNK)]  # junk first: they grab the slots
    gids += [r.submit("lvrf", good[i], keys=keys[i][None], **kw)
             for i in range(N_GOOD)]
    return gids


def bench_recovery() -> dict:
    spec, good, junk, keys = _problem()
    inner = _fresh_engine(spec, good, keys)
    r = rt.Runtime(failure=FAST_FAILURE)
    r.register("lvrf", _FailOnStep(inner, fail_steps=(3,)))
    with r:
        _submit_all(r, good, junk, keys)
        r.drain(timeout=600)
        events = r.stats()["lvrf"]["supervision"]["events"]
    t_fault = t_recovered = t_first = None
    for t, tag in events:
        if tag.startswith("fault") and t_fault is None:
            t_fault = t
        elif tag.startswith("recovered") and t_recovered is None:
            t_recovered = t
        elif tag == "first_completion_after_recovery":
            t_first = t
    tel = r.telemetry["lvrf"]
    assert None not in (t_fault, t_recovered, t_first), events
    return {
        "requests": {"good": N_GOOD, "junk_burn_to_max_iters": N_JUNK},
        "fault": "scripted InjectedFault at runtime step 3",
        "quarantine_s": round(t_recovered - t_fault, 4),
        "recovery_latency_s": round(t_first - t_fault, 4),
        "replayed_rows": tel.replayed,
        "recoveries": tel.recoveries,
        "note": ("recovery_latency_s = fault -> first post-recovery "
                 "completion: backoff + rebuild (recompile) + replay "
                 "catch-up on the runtime clock"),
    }


def _deadline_run(rate: float, deadline_s: float | None, seed: int):
    spec, good, junk, keys = _problem()
    inner = _fresh_engine(spec, good, keys)
    # max_faults=1: at most ONE recovery cycle per run, because the
    # covering budget is sized for exactly one — repeated faults restart
    # the replayed rows from scratch and no fixed budget covers that
    plan = flt.FaultPlan(seed=seed, step_error_rate=rate, max_faults=1)
    r = rt.Runtime(failure=FAST_FAILURE)
    r.register("lvrf", flt.ChaosEngine(inner, plan))
    with r:
        gids = _submit_all(r, good, junk, keys, deadline_s=deadline_s)
        out = r.drain(timeout=600, return_exceptions=True)
    hits = [o for o in out if not isinstance(o, Exception)]
    misses = [o for o in out if isinstance(o, flt.DeadlineExceededError)]
    other = [o for o in out
             if isinstance(o, Exception)
             and not isinstance(o, flt.DeadlineExceededError)]
    assert len(out) == len(gids) and not other, other  # every future resolves
    lat = [float(req.latency_s) for req in hits]
    return hits, misses, lat, r.telemetry["lvrf"].faults


def bench_deadlines(recovery_latency_s: float) -> dict:
    # tight budget: from a fault-free run, 2.5x its slowest request — any
    # recovery cycle necessarily blows it.  covering budget: tight plus
    # 1.5x one measured recovery cycle — one fault should be survivable.
    _, _, base_lat, _ = _deadline_run(0.0, None, seed=0)
    tight = round(2.5 * max(base_lat), 3)
    covering = round(tight + 1.5 * recovery_latency_s, 3)
    per_rate = {}
    for i, rate in enumerate(DEADLINE_RATES):
        entry = {}
        for label, budget in (("tight", tight), ("covering", covering)):
            hits, misses, _, faults = _deadline_run(rate, budget,
                                                    seed=101 + i)
            entry[label] = {
                "attained": len(hits),
                "deadline_missed": len(misses),
                "injected_step_faults": faults,
                "attainment": round(len(hits) / (len(hits) + len(misses)),
                                    3),
            }
        per_rate[f"{rate:g}"] = entry
    return {
        "requests_per_run": N_GOOD + N_JUNK,
        "tight_deadline_s": tight,
        "covering_deadline_s": covering,
        "deadline_rule": ("tight = 2.5x slowest fault-free request; "
                          "covering = tight + 1.5x measured recovery "
                          "latency; max_faults=1 so each run sees at most "
                          "one recovery cycle"),
        "per_step_fault_rate": per_rate,
    }


def bench() -> dict:
    rec = bench_recovery()
    return {"recovery": rec,
            "deadlines": bench_deadlines(rec["recovery_latency_s"])}


def run() -> list[dict]:
    b = bench()
    rec, dl = b["recovery"], b["deadlines"]
    att = " ".join(
        f"rate={k}:{v['tight']['attainment']}/{v['covering']['attainment']}"
        for k, v in dl["per_step_fault_rate"].items())
    return [
        row("fault_recovery",
            f"quarantine_replay(good={N_GOOD},junk={N_JUNK})",
            rec["recovery_latency_s"] * 1e6,
            f"quarantine_us={rec['quarantine_s']*1e6:.0f} "
            f"replayed={rec['replayed_rows']}"),
        row("fault_recovery",
            f"deadline_attainment(tight={dl['tight_deadline_s']}s,"
            f"covering={dl['covering_deadline_s']}s)",
            dl["covering_deadline_s"] * 1e6, f"tight/covering {att}"),
    ]


def main() -> None:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json")
    out = write_bench(
        path, "fault_recovery", bench(),
        workload=(f"{N_GOOD} LVRF row decodes + {N_JUNK} junk queries "
                  "(pinned keys, burn to max_iters) through one "
                  "supervised Runtime"),
        timing_mode=("CPU wall clock — NOT TPU-predictive; the "
                     "transferable signals are the recovery-cost "
                     "structure (backoff + rebuild/recompile dominate) "
                     "and the deadline tradeoff: tight budgets convert "
                     "a recovery cycle into structured misses, a "
                     "recovery-covering budget restores attainment"),
        config={"n_good": N_GOOD, "n_junk": N_JUNK})
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
