"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes artifacts/bench.json.
    PYTHONPATH=src python -m benchmarks.run [--only fig17 tab08 ...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    from benchmarks import (engine_serve, engine_sharded, factorizer_batch,
                            fault_recovery, kernels_micro, lm_serve,
                            paper_hardware, paper_tables, runtime_serve)

    mods = [paper_hardware, kernels_micro, paper_tables, engine_serve,
            engine_sharded, runtime_serve, lm_serve, fault_recovery]
    # the vmap-of-scalar baseline leg costs minutes in interpret mode, so the
    # factorizer comparison only runs when asked for (it also has its own
    # __main__ entry that writes BENCH_factorizer.json)
    if args.only and any("factorizer" in o for o in args.only):
        mods.insert(2, factorizer_batch)
    rows = []
    for mod in mods:
        try:
            rows += mod.run()
        except Exception as e:  # one env-sensitive suite must not kill the rest
            print(f"warning: {mod.__name__} failed: {type(e).__name__}: {e}",
                  file=sys.stderr)
    if args.only:
        rows = [r for r in rows if any(o in r["benchmark"] for o in args.only)]
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['benchmark']}/{r['name']},{r['us_per_call']},\"{r['derived']}\"")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
