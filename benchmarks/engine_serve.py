"""Engine continuous batching vs batch-and-wait waves at equal batch shapes.

R factorization requests (NVSA-shaped: padded attribute books, stochastic
Gauss-Seidel sweeps with restarts — high per-query iteration variance) are
served two ways with the SAME [N, F, D] batch shape:

  * ``wave``  — ``factorize_batch`` in batches of N; every wave runs to its
    batch-max iteration count, so fast queries idle behind the slowest slot
    (the pre-engine `solve()` pattern);
  * ``engine`` — ``Engine.submit/step/drain``: converged rows retire and are
    refilled from the queue mid-flight, so the batch stays full of live work.

Reported both as wall time (interpret-mode CPU — not TPU-predictive) and as
the structural metric that transfers: total resonator sweeps executed, i.e.
codebook HBM passes.  ``run()`` feeds the shared bench.json harness;
``python -m benchmarks.engine_serve`` writes BENCH_engine.json at the repo
root (the committed record for the serving acceptance bar).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, write_bench
from repro import engine as eng_mod
from repro.core import factorizer as fz
from repro.models import nvsa


def _problem(n_requests: int, seed: int = 0):
    cfg = nvsa.NVSAConfig()
    cbs, mask = nvsa.make_codebooks(jax.random.PRNGKey(0), cfg)
    fcfg = cfg.factorizer
    rng = jax.random.PRNGKey(seed)
    k_idx, k_noise, k_fact = jax.random.split(rng, 3)
    idxs = jnp.stack([jax.random.randint(jax.random.fold_in(k_idx, a),
                                         (n_requests,), 0, n)
                      for a, n in enumerate(nvsa.ATTR_SIZES)], axis=-1)
    qs = fz.bind_combo(cbs, idxs, fcfg.vsa)
    # heavy perception-like noise -> wide convergence-time spread (the
    # regime where batch-and-wait pays the slowest slot per wave)
    qs = qs + 1.4 * jnp.std(qs) * jax.random.normal(k_noise, qs.shape)
    keys = jax.random.split(k_fact, n_requests)
    return cbs, mask, fcfg, qs, keys


def bench(n_requests: int = 64, slots: int = 16) -> dict:
    cbs, mask, fcfg, qs, keys = _problem(n_requests)

    # --- wave baseline: batches of `slots`, each runs to batch-max iters ---
    waved = jax.jit(lambda q, k: fz._factorize_batched(q, cbs, k, fcfg, mask))
    jax.block_until_ready(waved(qs[:slots], keys[:slots]).indices)  # compile
    t0 = time.perf_counter()
    wave_iters, wave_lat, wave_sweeps = [], [], 0
    for w in range(0, n_requests, slots):
        res = waved(qs[w:w + slots], keys[w:w + slots])
        jax.block_until_ready(res.indices)
        it = np.asarray(res.iterations)
        wave_iters.append(it)
        wave_sweeps += int(it.max())
        wave_lat += [time.perf_counter() - t0] * it.shape[0]
    t_wave = time.perf_counter() - t0
    wave_iters = np.concatenate(wave_iters)

    # --- engine: continuous batching over the same shapes -----------------
    spec = eng_mod.ServeSpec("bench_nvsa_queries", cbs, fcfg, mask)
    e = eng_mod.Engine(spec, slots=slots, sweeps_per_step=4)
    # warm THIS engine's sweep/refill/decode programs outside the timed
    # region (the jitted closures are per-instance), then serve for real
    e.submit(qs[0], keys=keys[:1])
    e.drain()
    e.completed.clear()
    e.sweeps_total = e.steps_total = 0
    t0 = time.perf_counter()
    for i in range(n_requests):
        e.submit(qs[i], keys=keys[i:i + 1])
    done = e.drain()
    t_eng = time.perf_counter() - t0
    eng_lat = [r.latency_s for r in done]
    eng_iters = np.asarray([int(r.iterations[0]) for r in done])

    assert (eng_iters == wave_iters).all(), "per-request trajectories diverged"
    pct = lambda xs, p: float(np.percentile(np.asarray(xs), p))
    return {
        "n_requests": n_requests,
        "slots": slots,
        "iterations_mean": round(float(wave_iters.mean()), 2),
        "iterations_max": int(wave_iters.max()),
        "wave": {
            "wall_s": round(t_wave, 4),
            "requests_per_s": round(n_requests / t_wave, 2),
            "latency_p50_ms": round(pct(wave_lat, 50) * 1e3, 2),
            "latency_p99_ms": round(pct(wave_lat, 99) * 1e3, 2),
            "sweeps_total": wave_sweeps,
        },
        "engine": {
            "wall_s": round(t_eng, 4),
            "requests_per_s": round(n_requests / t_eng, 2),
            "latency_p50_ms": round(pct(eng_lat, 50) * 1e3, 2),
            "latency_p99_ms": round(pct(eng_lat, 99) * 1e3, 2),
            "sweeps_total": e.sweeps_total,
            "sweeps_per_step": e.sweeps_per_step,
        },
        "throughput_ratio_engine_over_wave": round(t_wave / t_eng, 2),
        "sweep_ratio_wave_over_engine": round(wave_sweeps / e.sweeps_total, 2),
    }


def bench_fused(n_requests: int = 320, slots: int = 256) -> dict:
    """Fused vs unfused Jacobi serving at N=256 slots (acceptance metric).

    The same LVRF row-decoding requests (bipolar MAP, deterministic Jacobi
    sweeps) served by two engines differing ONLY in where the sweep runs:
    the two-pass jnp path vs the fused Pallas kernel (interpret mode on CPU
    — wall times are NOT TPU-predictive).  Trajectories are asserted
    bit-identical; the transferable metric is structural: codebook HBM
    passes per iteration per factor — the two-pass sweep fetches X[f] once
    per row-tile for the similarity matmul and once for the projection
    (2 * ceil(N/Tn)), the fused kernel keeps it VMEM-resident across both
    (ceil(N/Tn)) — exactly halved.
    """
    from repro import engine as eng_api
    from repro.kernels.resonator_step import kernel as rsk
    from repro.models import lvrf

    spec_f = eng_api.registry.build("lvrf_rows", jax.random.PRNGKey(0),
                                    fused_step=True)
    spec_u = eng_api.registry.build("lvrf_rows", jax.random.PRNGKey(0),
                                    synchronous=True)
    cfg = lvrf.LVRFConfig()
    atoms = lvrf.init_atoms(jax.random.split(jax.random.PRNGKey(0))[0], cfg)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, cfg.n_values, (n_requests, 3)))
    qs = lvrf.encode_row(atoms, vals, cfg)
    keys = jax.random.split(jax.random.PRNGKey(9), n_requests)

    def serve(spec):
        e = eng_api.Engine(spec, slots=slots, sweeps_per_step=4)
        e.submit(qs[0], keys=keys[:1])  # warm the per-instance programs
        e.drain()
        e.completed.clear()
        e.sweeps_total = e.steps_total = 0
        t0 = time.perf_counter()
        ids = [e.submit(qs[i], keys=keys[i:i + 1]) for i in range(n_requests)]
        done = {r.id: r for r in e.drain()}
        wall = time.perf_counter() - t0
        traj = [(np.asarray(done[i].factorization.indices).tolist(),
                 np.asarray(done[i].iterations).tolist()) for i in ids]
        return e, wall, traj

    eng_f, t_f, traj_f = serve(spec_f)
    eng_u, t_u, traj_u = serve(spec_u)
    assert traj_f == traj_u, "fused trajectories diverged from unfused"
    tiles = -(-slots // rsk.row_tile(slots))
    return {
        "n_requests": n_requests,
        "slots": slots,
        "trajectories_bit_equal": True,
        "fused": {
            "wall_s": round(t_f, 4),
            "requests_per_s": round(n_requests / t_f, 2),
            "sweeps_total": eng_f.sweeps_total,
            "codebook_hbm_passes_per_iter_per_factor": tiles,
        },
        "unfused": {
            "wall_s": round(t_u, 4),
            "requests_per_s": round(n_requests / t_u, 2),
            "sweeps_total": eng_u.sweeps_total,
            "codebook_hbm_passes_per_iter_per_factor": 2 * tiles,
        },
        "codebook_hbm_pass_ratio_unfused_over_fused": 2.0,
    }


def run() -> list[dict]:
    e = bench()
    f = bench_fused()
    return [row(
        "engine_serve", f"continuous_vs_wave(R={e['n_requests']},N={e['slots']})",
        e["engine"]["wall_s"] * 1e6,
        f"wave_us={e['wave']['wall_s']*1e6:.0f} "
        f"throughput_ratio={e['throughput_ratio_engine_over_wave']}x "
        f"sweeps={e['engine']['sweeps_total']}(vs {e['wave']['sweeps_total']}) "
        f"p50={e['engine']['latency_p50_ms']}ms "
        f"p99={e['engine']['latency_p99_ms']}ms"), row(
        "engine_serve", f"fused_vs_unfused(R={f['n_requests']},N={f['slots']})",
        f["fused"]["wall_s"] * 1e6,
        f"unfused_us={f['unfused']['wall_s']*1e6:.0f} bit_equal=True "
        f"codebook_hbm_passes/iter/f="
        f"{f['fused']['codebook_hbm_passes_per_iter_per_factor']}"
        f"(vs {f['unfused']['codebook_hbm_passes_per_iter_per_factor']})")]


def main() -> None:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
    out = write_bench(
        path, "engine_serve",
        {"serving": bench(),
         "fused_serving": {
             "workload": ("LVRF row decoding (bipolar MAP, deterministic "
                          "Jacobi sweeps), F=3, M=10, D=2048, N=256 slots — "
                          "fused Pallas sweep vs two-pass jnp sweep, "
                          "bit-identical trajectories asserted"),
             "result": bench_fused(),
         }},
        workload=("NVSA attribute factorization queries (1.4-sigma query "
                  "noise), F=3, M=(5,6,10) padded, D=1024, Gauss-Seidel + "
                  "score noise 0.3 + restarts, max_iters=60"),
        timing_mode=("CPU wall clock — NOT TPU-predictive; the sweep "
                     "counts (codebook HBM passes) are the transferable "
                     "metric"))
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
