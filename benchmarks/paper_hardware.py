"""Hardware-side paper reproductions via cogsim: Figs. 11, 15-19, Tabs. II, V, X."""
from __future__ import annotations

import numpy as np

from benchmarks.common import TASKS, graph_flops_bytes, nvsa_op_graph, row
from repro.cogsim import model as hw
from repro.core import scheduler as sch


def tab02_kernel_analysis():
    """Compute/memory character of neural vs symbolic kernels (model-based)."""
    rows = []
    # sgemm: high reuse; circconv-as-elementwise streaming: ~zero reuse.
    m, k, n = 4096, 4096, 4096
    ai_gemm = 2 * m * k * n / ((m * k + k * n + m * n) * 4)
    d = 1024
    ai_vec = (2 * d * d) / (3 * d * d * 4)  # GPU gather-based circconv
    ai_elem = 1 / 12.0
    dev = hw.RTX2080TI
    for name, ai, symbolic in [("sgemm_nn", ai_gemm, False),
                               ("vectorized_elem(circconv)", ai_vec, True),
                               ("elementwise", ai_elem, True)]:
        ridge = dev.peak_flops / dev.mem_bw
        bound = "compute" if ai > ridge else "memory"
        util = min(1.0, ai / ridge)
        rows.append(row("tab02", name, None,
                        f"intensity={ai:.2f}FLOP/B {bound}-bound "
                        f"compute_util<={util:.1%} "
                        f"(paper: symbolic 2-3% compute, ~80-90% DRAM BW)"))
    return rows


def tab05_design_choice():
    return [row("tab05", r["config"], None,
                f"area={r['area']}x latency={r['latency']}x energy={r['energy']}x "
                f"util={r['utilization']:.0%}")
            for r in hw.heterogeneous_pe_comparison()]


def fig11_bs_dataflow():
    """Fig. 11a/c: BS dataflow vs GEMV on a systolic cell for 3 circconvs."""
    rows = []
    k, d = 3, 32
    cell = hw.ArrayConfig("cell", num_cells=1, cell_dim=32)
    bs = hw.bs_circconv_cycles(cell, k, d)
    sa = hw.sa_circconv_as_gemv_cycles(
        hw.ArrayConfig("sa", num_cells=1, cell_dim=32, reconfigurable=False,
                       cwp=False, scwp=False), k, d)
    rows.append(row("fig11", "bs-dataflow(3xconv,d=32)", None,
                    f"cycles={bs['compute_cycles']:.0f} footprint=O(d) "
                    f"mapping={bs['mapping']}"))
    rows.append(row("fig11", "tpu-gemv(3xconv,d=32)", None,
                    f"cycles={sa['compute_cycles']:.0f} footprint=O(d^2) "
                    f"speedup={sa['compute_cycles']/bs['compute_cycles']:.1f}x"))
    # roofline comparison at 2^14 PEs
    d = 1024
    ai_bs = d * (2 * d - 1) / (3 * d)  # paper's CogSys arithmetic intensity
    ai_gpu = d * (2 * d - 1) / (d * d + 2 * d)  # paper's GPU intensity
    rows.append(row("fig11", "arithmetic-intensity", None,
                    f"cogsys_bs={ai_bs:.0f}FLOP/elem gpu={ai_gpu:.2f}FLOP/elem "
                    f"-> BS compute-bound, GPU memory-bound"))
    return rows


def fig17_circconv_speedup():
    """Sweep vector dim and #convs: CogSys vs TPU-like SA vs GPU."""
    rows = []
    best_tpu, best_gpu = 0.0, 0.0
    for d in (64, 128, 256, 512, 1024):
        for k in (16, 64, 210, 512):
            c = hw.bs_circconv_cycles(hw.COGSYS, k, d)["cycles"] / hw.COGSYS.freq_hz
            t = hw.sa_circconv_as_gemv_cycles(hw.TPU_LIKE, k, d)["cycles"] \
                / hw.TPU_LIKE.freq_hz
            flops = 2.0 * k * d * d
            g = hw.gpu_op_seconds(hw.RTX2080TI, flops, k * (d * d + 2 * d) * 4,
                                  symbolic=True)
            best_tpu = max(best_tpu, t / c)
            best_gpu = max(best_gpu, g / c)
            if (d, k) in ((1024, 210), (64, 512), (1024, 512)):
                rows.append(row("fig17", f"d={d},k={k}", None,
                                f"vs_tpu={t/c:.1f}x vs_gpu={g/c:.1f}x"))
    rows.append(row("fig17", "max-speedup", None,
                    f"vs_tpu={best_tpu:.1f}x vs_gpu={best_gpu:.1f}x "
                    f"(paper: 75.96x / 18.90x)"))
    return rows


def _e2e_seconds(task: dict, device) -> dict:
    """End-to-end seconds per task batch on each platform."""
    ops = nvsa_op_graph(task, batches=2)
    nf, sf, nb, sb = graph_flops_bytes(ops)
    if isinstance(device, hw.GPURoofline):
        t = hw.gpu_op_seconds(device, nf, nb, symbolic=False) + \
            hw.gpu_op_seconds(device, sf, sb, symbolic=True)
        return {"seconds": t}
    s = sch.schedule(ops, device, interleave=True)
    return {"seconds": s.makespan / device.freq_hz, "util": s.utilization}


def fig15_e2e_runtime():
    rows = []
    for tname, task in TASKS.items():
        cog = _e2e_seconds(task, hw.COGSYS)["seconds"]
        per = {dev.name: _e2e_seconds(task, dev)["seconds"]
               for dev in (hw.RTX2080TI, hw.XEON_CPU, hw.JETSON_TX2, hw.XAVIER_NX)}
        sp = {k: v / cog for k, v in per.items()}
        rows.append(row("fig15", tname, cog * 1e6 / 2,
                        f"per-task={cog/2*1e3:.2f}ms realtime={'YES' if cog/2 < 0.3 else 'no'} "
                        + " ".join(f"vs_{k}={v:.0f}x" for k, v in sp.items())))
    return rows


def fig16_energy():
    rows = []
    powers = {"rtx2080ti": 250, "xeon": 145, "tx2": 15, "nx": 20}
    for tname, task in TASKS.items():
        cog_t = _e2e_seconds(task, hw.COGSYS)["seconds"]
        cog_e = cog_t * hw.area_power(hw.COGSYS, "int8")["power_w"]
        effs = {}
        for dev in (hw.RTX2080TI, hw.XEON_CPU, hw.JETSON_TX2, hw.XAVIER_NX):
            t = _e2e_seconds(task, dev)["seconds"]
            effs[dev.name] = (t * powers[dev.name]) / cog_e
        rows.append(row("fig16", tname, None,
                        " ".join(f"eff_vs_{k}={v:.0f}x" for k, v in effs.items())
                        + " (paper: ~2 orders vs GPU)"))
    return rows


def fig18_ml_accelerators():
    rows = []
    task = TASKS["RAVEN"]
    ops = nvsa_op_graph(task, batches=2)

    def subset(pred):
        keep = [o for o in ops if pred(o)]
        names = {o.name for o in keep}
        import dataclasses as dc
        return [dc.replace(o, deps=tuple(d for d in o.deps if d in names))
                for o in keep]

    neural = subset(lambda o: not o.symbolic)
    symbolic = subset(lambda o: o.symbolic)
    for dev in (hw.COGSYS, hw.TPU_LIKE, hw.GEMMINI_LIKE, hw.MTIA_LIKE):
        tn = sch.schedule(neural, dev, interleave=True).makespan / dev.freq_hz
        ts = sch.schedule(symbolic, dev, interleave=True).makespan / dev.freq_hz
        te = sch.schedule(ops, dev, interleave=True).makespan / dev.freq_hz
        rows.append(row("fig18", dev.name, te * 1e6,
                        f"neural={tn*1e3:.2f}ms symbolic={ts*1e3:.2f}ms "
                        f"e2e={te*1e3:.2f}ms"))
    base = sch.schedule(ops, hw.TPU_LIKE, interleave=True).makespan
    ours = sch.schedule(ops, hw.COGSYS, interleave=True).makespan
    rows.append(row("fig18", "e2e-speedup-vs-tpu-like", None, f"{base/ours:.1f}x"))
    return rows


def fig19_hw_ablation():
    rows = []
    task = TASKS["RAVEN"]
    ops = nvsa_op_graph(task, batches=3)
    full = sch.schedule(ops, hw.COGSYS, interleave=True).makespan
    no_sched = sch.schedule(ops, hw.COGSYS, interleave=False).makespan
    no_so = sch.schedule(ops, hw.COGSYS_NO_SCALEOUT, interleave=False).makespan
    no_nspe = sch.schedule(ops, hw.COGSYS_NO_NSPE, interleave=False).makespan
    rows.append(row("fig19", "full-cogsys", None, f"makespan={full:.0f}cyc"))
    rows.append(row("fig19", "w/o-adSCH", None,
                    f"+{(no_sched-full)/no_sched:.0%} runtime (paper: adSCH saves ~28%)"))
    rows.append(row("fig19", "w/o-adSCH+scale-out", None,
                    f"reduction-vs-full={(no_so-full)/no_so:.0%} (paper: 61%)"))
    rows.append(row("fig19", "w/o-adSCH+SO+nsPE", None,
                    f"reduction-vs-full={(no_nspe-full)/no_nspe:.0%} (paper: 71%)"))
    return rows


def tab10_codesign():
    rows = []
    task = TASKS["RAVEN"]
    nx = hw.XAVIER_NX
    ops_f = nvsa_op_graph(task, batches=2)
    # NVSA baseline: its own resonator needs ~15% more iterations without the
    # stochasticity trick (our Tab. VIII measurement) AND sweeps the ~38 MB
    # product codebook once per panel for the attribute lookup.
    ops_b = nvsa_op_graph(dict(task, iters=int(task["iters"] * 1.2)), batches=2)
    nf, sf, nb, sb = graph_flops_bytes(ops_f)
    _, sf_b, _, sb_b = graph_flops_bytes(ops_b)
    n_codebook = 38 * 2**20 // (task["d"] * 4)
    sf_b += 2.0 * 2 * task["panels"] * task["d"] * n_codebook
    sb_b += 2 * task["panels"] * (n_codebook * task["d"]) * 4.0
    t_base = hw.gpu_op_seconds(nx, nf, nb, False) + \
        hw.gpu_op_seconds(nx, sf_b, sb_b, True)
    t_alg = hw.gpu_op_seconds(nx, nf, nb, False) + hw.gpu_op_seconds(nx, sf, sb, True)
    t_cog = sch.schedule(ops_f, hw.COGSYS, interleave=True).makespan / hw.COGSYS.freq_hz
    rows.append(row("tab10", "NVSA@XavierNX", t_base * 1e6, "100%"))
    rows.append(row("tab10", "CogSysAlg@XavierNX", t_alg * 1e6,
                    f"{t_alg/t_base:.1%} (paper: 89.5%)"))
    rows.append(row("tab10", "CogSysAlg@CogSysAccel", t_cog * 1e6,
                    f"{t_cog/t_base:.2%} (paper: 1.76%)"))
    return rows


def run():
    rows = []
    for fn in (tab02_kernel_analysis, tab05_design_choice, fig11_bs_dataflow,
               fig04c_scalability, fig15_e2e_runtime, fig16_energy,
               fig17_circconv_speedup, fig18_ml_accelerators, fig19_hw_ablation,
               tab10_codesign):
        rows += fn()
    return rows


def fig04c_scalability():
    """Fig. 4c: neuro/symbolic runtime share is stable as task size grows
    (2x2 -> 3x3 RPM), while total runtime scales ~5x on the GPU baselines."""
    rows = []
    base = dict(TASKS["RAVEN"])
    small = dict(base, panels=7, k=base["k"] // 2, iters=base["iters"] // 2)
    out = {}
    for name, task in (("2x2", small), ("3x3", base)):
        ops = nvsa_op_graph(task, batches=2)
        nf, sf, nb, sb = graph_flops_bytes(ops)
        t_n = hw.gpu_op_seconds(hw.RTX2080TI, nf, nb, symbolic=False)
        t_s = hw.gpu_op_seconds(hw.RTX2080TI, sf, sb, symbolic=True)
        out[name] = (t_n, t_s)
        rows.append(row("fig04c", f"rpm-{name}", (t_n + t_s) * 1e6,
                        f"symbolic_share={t_s/(t_n+t_s):.1%}"))
    scale = sum(out["3x3"]) / sum(out["2x2"])
    rows.append(row("fig04c", "task-size-scaling", None,
                    f"3x3/2x2 runtime={scale:.2f}x, share stable "
                    f"(paper: 5.02x avg, 91.6%->87.4%)"))
    return rows
