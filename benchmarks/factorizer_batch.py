"""Batch-native factorizer vs the old vmap-of-scalar formulation.

Times the fused bipolar resonator loop both ways at N in {16, 64, 256} and
records the structural metrics that transfer to TPU regardless of the
interpret-mode wall clock:

  * per-iteration codebook HBM passes — the vmap-of-scalar kernel sees
    [1, D] blocks, so every query re-streams every codebook each sweep
    (N passes/iter); the batch-native kernel tiles rows (ceil(N/Tn) passes),
  * per-query iteration counts (mean vs max) — the batched while_loop runs
    to the batch max, but converged queries freeze behind the done mask, so
    mean << max quantifies the masked-out work.

``run()`` feeds the shared bench.json harness;
``python -m benchmarks.factorizer_batch`` also writes BENCH_factorizer.json
at the repo root (the committed record for the batch-native acceptance bar).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import row, timeit, write_bench
from repro.core import factorizer as fz
from repro.core import vsa
from repro.kernels.resonator_step import kernel as rsk

_TN = 128  # row tile of the batched fused resonator kernel


def _fused_cfg(D: int = 512) -> fz.FactorizerConfig:
    return fz.FactorizerConfig(
        vsa=vsa.VSAConfig(D, D), num_factors=3, codebook_size=16,
        algebra="bipolar", synchronous=True, fused_step=True,
        max_iters=30, conv_threshold=0.5)


def _problem(cfg: fz.FactorizerConfig, n: int, seed: int = 0):
    cbs = fz.make_codebooks(jax.random.PRNGKey(1), cfg)
    idxs = jax.random.randint(jax.random.PRNGKey(seed), (n, cfg.num_factors),
                              0, cfg.codebook_size)
    return cbs, fz.bind_combo(cbs, idxs, cfg.vsa)


def bench(ns=(16, 64, 256)) -> list[dict]:
    cfg = _fused_cfg()
    key = jax.random.PRNGKey(2)
    entries = []
    for n in ns:
        cbs, qs = _problem(cfg, n)
        keys = jax.random.split(key, n)
        batch_native = jax.jit(
            lambda q: fz.factorize_batch(q, cbs, key, cfg).indices)
        vmap_scalar = jax.jit(jax.vmap(  # the pre-batch-native formulation
            lambda q, k: fz.factorize(q, cbs, k, cfg).indices))
        t_b = timeit(batch_native, qs, warmup=1, iters=3)
        t_v = timeit(vmap_scalar, qs, keys, warmup=1, iters=1)
        res = fz.factorize_batch(qs, cbs, key, cfg)
        iters = np.asarray(res.iterations)
        tn = rsk.row_tile(n, _TN)  # the kernel's actual tile policy
        entries.append({
            "n": n,
            "wall_s_batch_native": round(t_b, 4),
            "wall_s_vmap_of_scalar": round(t_v, 4),
            "speedup": round(t_v / t_b, 2),
            "row_tile": tn,
            "codebook_hbm_passes_per_iter": {
                "vmap_of_scalar": n,
                "batch_native": -(-n // tn),
            },
            "iterations_per_query": iters.tolist(),
            "iterations_mean": round(float(iters.mean()), 2),
            "iterations_max": int(iters.max()),
            "converged_frac": round(float(np.asarray(res.converged).mean()), 3),
        })
    return entries


def run() -> list[dict]:
    rows = []
    for e in bench():
        rows.append(row(
            "factorizer", f"batch_native_vs_vmap(n={e['n']})",
            e["wall_s_batch_native"] * 1e6,
            f"vmap_of_scalar_us={e['wall_s_vmap_of_scalar']*1e6:.0f} "
            f"speedup={e['speedup']}x "
            f"cb_passes/iter={e['codebook_hbm_passes_per_iter']['batch_native']}"
            f"(vs {e['codebook_hbm_passes_per_iter']['vmap_of_scalar']}) "
            f"iters mean={e['iterations_mean']} max={e['iterations_max']}"))
    return rows


def main() -> None:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_factorizer.json")
    out = write_bench(
        path, "factorizer_batch", bench(),
        workload="bipolar fused resonator, F=3, M=16, D=512, max_iters=30",
        timing_mode=("Pallas interpret on CPU — wall time is NOT "
                     "TPU-predictive; the HBM-pass and iteration metrics are"))
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
