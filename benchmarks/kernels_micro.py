"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU-predictive;
the derived column carries the structural metrics that are)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.quantization import quantize
from repro.kernels.circconv import kernel as cck
from repro.kernels.circconv import ref as ccr
from repro.kernels.resonator_step import kernel as rsk
from repro.kernels.resonator_step import ref as rsr
from repro.kernels.similarity import kernel as simk


def run():
    rows = []
    for n, L in [(64, 256), (256, 1024)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, L))
        y = jax.random.normal(jax.random.PRNGKey(1), (n, L))
        t_k = timeit(lambda a, b: cck.circconv_rows(a, b, interpret=True), x, y,
                     warmup=1, iters=3)
        t_r = timeit(jax.jit(ccr.circconv_rows_ref), x, y, warmup=1, iters=3)
        flops = 2 * n * L * L
        hbm = 3 * n * L * 4
        rows.append(row("kernels", f"circconv_rows(n={n},L={L})", t_k * 1e6,
                        f"intensity={flops/hbm:.0f}FLOP/B hbm_per_conv=O(d) "
                        f"ref_us={t_r*1e6:.0f}"))
    q = jax.random.normal(jax.random.PRNGKey(2), (64, 1024))
    w = quantize(jax.random.normal(jax.random.PRNGKey(3), (512, 1024)), "int8")
    t = timeit(lambda a: simk.similarity_int8(a, w.values, w.scale,
                                              interpret=True), q,
               warmup=1, iters=3)
    rows.append(row("kernels", "similarity_int8(64x512x1024)", t * 1e6,
                    "codebook HBM traffic 1B/elem (4x less than fp32)"))
    # fused resonator sweep: [Tn, D]-tiled MXU matmuls, codebook read once per
    # (factor, row-tile) instead of once per query per factor
    N, F, M, D = 64, 3, 16, 512
    kb = jax.random.split(jax.random.PRNGKey(4), 3)
    sgn = lambda k, s: jnp.where(jax.random.bernoulli(k, shape=s), 1.0, -1.0)
    cbs = sgn(kb[0], (F, M, D))
    qs, est = sgn(kb[1], (N, D)), sgn(kb[2], (N, F, D))
    t_k = timeit(lambda a, b: rsk.resonator_step_batch(a, b, cbs, interpret=True),
                 qs, est, warmup=1, iters=3)
    t_r = timeit(jax.jit(lambda a, b: rsr.resonator_step_batch_ref(a, b, cbs)),
                 qs, est, warmup=1, iters=3)
    tiles = -(-N // rsk.row_tile(N))
    rows.append(row("kernels", f"resonator_step_batch(n={N},f={F},m={M},d={D})",
                    t_k * 1e6,
                    f"codebook_hbm_passes/iter={tiles} (vs {N} at batch-1) "
                    f"ref_us={t_r*1e6:.0f}"))
    # mask-aware fused sweep: the validity mask rides in VMEM with X[f], so
    # budget-masked serving keeps the single codebook pass per (f, row-tile)
    # (vs 2*tiles for the two-pass masked sweep the old guard fell back to)
    mask = jnp.stack([jnp.arange(M) < m for m in (5, M, 9)])
    t_m = timeit(lambda a, b: rsk.resonator_step_batch_masked(
        a, b, cbs, mask, interpret=True), qs, est, warmup=1, iters=3)
    t_mr = timeit(jax.jit(lambda a, b: rsr.resonator_step_batch_masked_ref(
        a, b, cbs, mask)), qs, est, warmup=1, iters=3)
    rows.append(row("kernels",
                    f"resonator_step_batch_masked(n={N},f={F},m={M},d={D})",
                    t_m * 1e6,
                    f"codebook_hbm_passes/iter={tiles} (vs {2*tiles} unfused "
                    f"masked) mask_bytes/f={M*4} ref_us={t_mr*1e6:.0f}"))
    # shard-aware fused sweep: one model shard's row block; emits raw local
    # scores + the partial projection for the packed one-psum-per-factor
    # gather (psum payload 4*(M+D) B/row/factor, same as the unfused path)
    M2 = M // 2
    t_l = timeit(lambda a, b: rsk.resonator_step_batch_local(
        a, b, cbs[:, :M2], mask[:, :M2], interpret=True), qs, est,
        warmup=1, iters=3)
    rows.append(row("kernels",
                    f"resonator_step_batch_local(n={N},f={F},m={M2},d={D})",
                    t_l * 1e6,
                    f"local_codebook_hbm_passes/iter={tiles} "
                    f"psum_payload_B/row/f={4*(M+D)}"))
    return rows
