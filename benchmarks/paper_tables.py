"""Algorithm-side paper reproductions: Tabs. VII, VIII, IX + Figs. 4, 5, 6.

These run REAL JAX computations on CPU (accuracy, wall-time shares, memory);
the hardware-side tables live in paper_hardware.py (cogsim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import codebook as cbk
from repro.core import factorizer as fz
from repro.core import symbolic as sym
from repro.core import vsa
from repro.cogsim import model as hw
from repro.data import raven


def _fact_cfg(F=3, M=10, noise=0.3, restarts=20, fmt="fp32"):
    return fz.FactorizerConfig(
        vsa=vsa.VSAConfig(1024, 4), num_factors=F, codebook_size=M,
        algebra="unitary", activation="abs", noise_std=noise,
        restart_every=restarts, max_iters=100, conv_threshold=0.55,
        codebook_fmt=fmt)


def _accuracy(cfg, trials=64, seed=0, codebooks=None, qnoise=0.3):
    cbs = fz.make_codebooks(jax.random.PRNGKey(1), cfg)
    idxs = jax.random.randint(jax.random.PRNGKey(seed), (trials, cfg.num_factors),
                              0, cfg.codebook_size)
    qs = fz.bind_combo(cbs, idxs, cfg.vsa)  # batched bind, no vmap
    if qnoise:
        qs = qs + qnoise * jnp.std(qs) * jax.random.normal(
            jax.random.PRNGKey(seed + 1), qs.shape)
    cb_in = codebooks(cbs) if codebooks else cbs
    res = fz.factorize_batch(qs, cb_in, jax.random.PRNGKey(2), cfg)
    return (float((res.indices == idxs).all(-1).mean()),
            float(res.iterations.mean()))


# Tab. VII: factorization accuracy across the 14 RAVEN/PGM scenarios.
_SCENARIOS = {  # constellation analogues vary (F, M); rule analogues vary query mix
    "2x2Grid": (4, 10), "3x3Grid": (4, 10), "Left-Right": (3, 10),
    "Up-Down": (3, 10), "Center": (3, 10), "O-IC": (4, 10), "DistFour": (4, 10),
    "Constant": (3, 10), "Progression": (3, 10), "XOR": (3, 16), "AND": (3, 16),
    "OR": (3, 16), "Arithmetic": (3, 16), "Distribution": (3, 16),
}


def tab07_factorization_accuracy():
    rows = []
    accs_ours, accs_base = [], []
    for i, (name, (F, M)) in enumerate(_SCENARIOS.items()):
        ours, _ = _accuracy(_fact_cfg(F, M), trials=48, seed=i)
        base, _ = _accuracy(_fact_cfg(F, M, noise=0.0, restarts=0),
                            trials=48, seed=i)
        accs_ours.append(ours)
        accs_base.append(base)
        rows.append(row("tab07", name, None,
                        f"ours={ours:.3f} baseline[50-style]={base:.3f}"))
    rows.append(row("tab07", "average", None,
                    f"ours={np.mean(accs_ours):.3f} baseline={np.mean(accs_base):.3f} "
                    f"(paper: 95.4% vs 95.3%)"))
    return rows


def tab08_algorithm_opt():
    """Accuracy + memory: exhaustive codebook vs factorization vs +int8."""
    rows = []
    ds = raven.RavenDataset(raven.RavenConfig(batch_size=128, render=False))
    b = ds.next_batch()
    grids = {a: jnp.eye(raven.ATTR_SIZES[a])[b[f"grid_{a}"]] for a in raven.ATTRS}
    cands = {a: jnp.asarray(b[f"cand_{a}"]) for a in raven.ATTRS}
    pred = sym.solve_attribute_grids(grids, cands)
    oracle = float((np.asarray(pred) == b["answer"]).mean())

    cfg = _fact_cfg()
    acc_f, it_f = _accuracy(cfg)
    acc_q, it_q = _accuracy(
        _fact_cfg(fmt="int8"), codebooks=lambda c: fz.quantize_codebooks(c, "int8"))
    mem = fz.codebook_bytes(cfg)
    mem_q = mem["factorized_bytes"] // 4
    # total model footprint = CNN frontend params + symbolic codebook(s),
    # the quantity the paper's #Parameters row tracks (38 -> 32 -> 8 MB).
    from repro.models import cnn as cnn_mod
    from repro.models import nvsa as nvsa_mod
    cnn_bytes = cnn_mod.num_params(
        cnn_mod.init(jax.random.PRNGKey(0), nvsa_mod.NVSAConfig().cnn)) * 4
    rows.append(row("tab08", "abduction-oracle(RAVEN)", None, f"acc={oracle:.3f}"))
    rows.append(row("tab08", "NVSA-style(product-codebook)", None,
                    f"model={(cnn_bytes+mem['product_bytes'])/2**20:.1f}MB acc=1.000"))
    rows.append(row("tab08", "factorized+stochasticity", None,
                    f"model={(cnn_bytes+mem['factorized_bytes'])/2**20:.2f}MB "
                    f"acc={acc_f:.3f} iters={it_f:.1f}"))
    rows.append(row("tab08", "factorized+int8", None,
                    f"model={(cnn_bytes//4+mem_q)/2**20:.2f}MB acc={acc_q:.3f} "
                    f"iters={it_q:.1f} (paper: 38->32->8MB at parity)"))
    return rows


def tab09_precision():
    rows = []
    for fmt, key in [("fp32", "fp32"), ("fp8_e4m3", "fp8"), ("int8", "int8")]:
        if fmt == "fp32":
            acc, _ = _accuracy(_fact_cfg())
        else:
            acc, _ = _accuracy(_fact_cfg(fmt=fmt),
                               codebooks=lambda c: fz.quantize_codebooks(c, fmt))
        a, p = hw._ARRAY_AP[key]
        sa, sp = hw._SIMD_AP[key]
        rows.append(row("tab09", key, None,
                        f"fact_acc={acc:.3f} array={a}mm2/{p}mW simd={sa}mm2/{sp}mW"))
    a32, _ = hw._ARRAY_AP["fp32"]
    a8, p8 = hw._ARRAY_AP["int8"]
    _, p32 = hw._ARRAY_AP["fp32"]
    rows.append(row("tab09", "int8-vs-fp32", None,
                    f"area_saving={a32/a8:.2f}x power_saving={p32/p8:.2f}x "
                    f"(paper: 7.71x / 4.02x)"))
    return rows


def fig04_runtime_memory():
    """Neural-vs-symbolic runtime share of the real pipeline on CPU."""
    import pickle
    from repro.models import cnn, nvsa
    cfg = nvsa.NVSAConfig()
    k_cb, k_p = jax.random.split(jax.random.PRNGKey(0))
    cbs, mask = nvsa.make_codebooks(k_cb, cfg)
    try:
        params = jax.tree.map(jnp.asarray, pickle.load(
            open("artifacts/nvsa_frontend.pkl", "rb")))
    except Exception:
        params = cnn.init(k_p, cfg.cnn)
    ds = raven.RavenDataset(raven.RavenConfig(batch_size=16, seed=5))
    b = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    imgs = b["images"].reshape(-1, 32, 32)

    perceive = jax.jit(lambda im: nvsa.perceive(params, im, cfg, cbs))
    t_neural = timeit(perceive, imgs)
    qs = perceive(imgs)
    factorize = jax.jit(lambda q: fz.factorize_batch(
        q, cbs, jax.random.PRNGKey(0), cfg.factorizer, mask).indices)
    t_sym = timeit(factorize, qs)
    total = t_neural + t_sym
    rows = [
        row("fig04", "neural-perception", t_neural * 1e6,
            f"share={t_neural/total:.1%}"),
        row("fig04", "symbolic-factorize", t_sym * 1e6,
            f"share={t_sym/total:.1%} (paper: symbolic dominates, e.g. 87%)"),
        row("fig04", "memory-codebook", None,
            f"product={fz.codebook_bytes(cfg.factorizer)['product_bytes']/2**20:.0f}MB"
            f" factorized={fz.codebook_bytes(cfg.factorizer)['factorized_bytes']/2**20:.2f}MB"),
    ]
    return rows


def fig05_roofline():
    """Arithmetic intensity of neural vs symbolic modules (cost_analysis)."""
    from repro.models import cnn, nvsa
    cfg = nvsa.NVSAConfig()
    params = cnn.init(jax.random.PRNGKey(0), cfg.cnn)
    imgs = jnp.zeros((128, 32, 32))
    from repro.compat import cost_analysis
    c_n = jax.jit(lambda im: cnn.apply(params, im, cfg.cnn)["query"]).lower(imgs).compile()
    ca_n = cost_analysis(c_n)
    cbs = fz.make_codebooks(jax.random.PRNGKey(1), cfg.factorizer)
    qs = jnp.zeros((128, 1024))
    # one unbind+similarity sweep (the symbolic inner loop, loop-free for XLA)
    def sym_step(q):
        est = jnp.ones((128, 3, 1024))
        ub = fz._unbind_all_but_one(q, est, cfg.factorizer)  # batched, no vmap
        return jnp.einsum("nfd,fmd->nfm", ub, cbs)
    c_s = jax.jit(sym_step).lower(qs).compile()
    ca_s = cost_analysis(c_s)
    ai_n = ca_n["flops"] / max(ca_n["bytes accessed"], 1)
    ai_s = ca_s["flops"] / max(ca_s["bytes accessed"], 1)
    ridge = hw.RTX2080TI.peak_flops / hw.RTX2080TI.mem_bw  # paper profiles 2080Ti
    return [
        row("fig05", "neural-module", None,
            f"intensity={ai_n:.1f}FLOP/B {'compute' if ai_n > ridge else 'memory'}-bound"),
        row("fig05", "symbolic-module", None,
            f"intensity={ai_s:.1f}FLOP/B {'compute' if ai_s > ridge else 'memory'}-bound "
            f"(paper: neuro compute-bound, symbolic memory-bound)"),
    ]


def fig06_symbolic_breakdown():
    """Runtime split of symbolic ops: circconv vs similarity vs elementwise."""
    cfg = _fact_cfg()
    cbs = fz.make_codebooks(jax.random.PRNGKey(1), cfg)
    qs = jax.random.normal(jax.random.PRNGKey(0), (256, 1024))
    est = jax.random.normal(jax.random.PRNGKey(2), (256, 3, 1024))
    unbind = jax.jit(lambda q, e: fz._unbind_all_but_one(q, e, cfg))  # batch-native
    t_cc = timeit(unbind, qs, est)
    ub = unbind(qs, est)
    simi = jax.jit(lambda u: jnp.einsum("nfd,fmd->nfm", u, cbs))
    t_sim = timeit(simi, ub)
    norm = jax.jit(lambda u: vsa.normalize_unitary(u, cfg.vsa))
    t_el = timeit(norm, ub)
    tot = t_cc + t_sim + t_el
    return [
        row("fig06", "circconv(unbind)", t_cc * 1e6, f"share={t_cc/tot:.1%}"),
        row("fig06", "similarity(matvec)", t_sim * 1e6, f"share={t_sim/tot:.1%}"),
        row("fig06", "elementwise(norm)", t_el * 1e6,
            f"share={t_el/tot:.1%} (paper: circconv+matvec ~80%)"),
    ]


def run():
    rows = []
    for fn in (fig04_runtime_memory, fig05_roofline, fig06_symbolic_breakdown,
               tab07_factorization_accuracy, tab08_algorithm_opt, tab09_precision):
        rows += fn()
    return rows
