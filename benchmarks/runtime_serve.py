"""Mixed-workload online serving: one Runtime vs per-engine sync drains.

Three engines (NVSA-shaped factorization queries, LVRF row decoding, LM
greedy decode on the smoke transformer) serve the same request sets two
ways:

  * ``sync``    — the pre-runtime pattern: each engine alone, submit
    everything, ``drain()``, one engine after another (requests of engine B
    wait for ALL of engine A);
  * ``runtime`` — one :class:`repro.runtime.Runtime`: all requests
    submitted up front as futures, the background stepper interleaves the
    engines by adSCH-modeled step cost x queue depth.

On one host CPU the interleave cannot mint compute, so the aggregate
requests/s land close to 1x — the serving win is the LATENCY profile:
nobody queues behind a foreign workload's full drain, so mixed-traffic p50
collapses (the Fig. 13b utilization argument at request granularity).
``run()`` feeds the shared bench.json harness; ``python -m
benchmarks.runtime_serve`` writes BENCH_runtime.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, write_bench
from repro import engine as eng_mod
from repro import runtime as rt
from repro.configs.registry import ARCHS
from repro.core import factorizer as fz
from repro.models import lvrf, nvsa
from repro.nn import transformer as T

R_NVSA, R_LVRF, R_LM = 16, 24, 4
LM_GEN = 16


def _problems(seed: int = 0):
    ncfg = nvsa.NVSAConfig()
    cbs, mask = nvsa.make_codebooks(jax.random.PRNGKey(0), ncfg)
    k_idx, k_noise, k_fact = jax.random.split(jax.random.PRNGKey(seed), 3)
    idxs = jnp.stack([jax.random.randint(jax.random.fold_in(k_idx, a),
                                         (R_NVSA,), 0, n)
                      for a, n in enumerate(nvsa.ATTR_SIZES)], axis=-1)
    nq = fz.bind_combo(cbs, idxs, ncfg.factorizer.vsa)
    nq = nq + 1.4 * jnp.std(nq) * jax.random.normal(k_noise, nq.shape)
    nkeys = jax.random.split(k_fact, R_NVSA)
    nspec = eng_mod.ServeSpec("bench_nvsa_queries", cbs, ncfg.factorizer, mask)

    lspec = eng_mod.registry.build("lvrf_rows", jax.random.PRNGKey(0))
    lcfg = lvrf.LVRFConfig()
    atoms = lvrf.init_atoms(jax.random.split(jax.random.PRNGKey(0))[0], lcfg)
    vals = jnp.asarray(np.random.default_rng(seed).integers(
        0, lcfg.n_values, (R_LVRF, 3)))
    lq = lvrf.encode_row(atoms, vals, lcfg)

    mcfg = ARCHS["llama3.2-3b"].smoke()
    params, _ = T.init(jax.random.PRNGKey(0), mcfg)
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (8,), 0, mcfg.vocab)
               for i in range(R_LM)]
    return (nspec, nq, nkeys), (lspec, lq), (mcfg, params, prompts)


def _make_engines(nspec, mcfg, params, lspec):
    engines = {
        "nvsa": eng_mod.Engine(nspec, slots=8, sweeps_per_step=4),
        "lvrf": eng_mod.Engine(lspec, slots=8),
        "lm": rt.LMEngine(mcfg, params, slots=4,
                          max_len=8 + LM_GEN + 1, decode_per_step=2),
    }
    return engines


def _warm(engines, nq, nkeys, lq, prompts):
    """Compile every engine's programs outside the timed region, then clear
    the serving counters."""
    engines["nvsa"].submit(nq[0], keys=nkeys[:1])
    engines["lvrf"].submit(lq[0])
    engines["lm"].submit(prompts[0], max_new_tokens=2)
    for e in engines.values():
        e.drain()
        e.completed.clear()
    for e in ("nvsa", "lvrf"):
        engines[e].sweeps_total = engines[e].steps_total = 0


def _submit_all(submit, nq, nkeys, lq, prompts) -> list:
    """Interleave the three request classes round-robin; returns
    (workload, handle) pairs."""
    out = []
    n = max(R_NVSA, R_LVRF, R_LM)
    for i in range(n):
        if i < R_NVSA:
            out.append(("nvsa", submit("nvsa", nq[i], keys=nkeys[i:i + 1])))
        if i < R_LVRF:
            out.append(("lvrf", submit("lvrf", lq[i])))
        if i < R_LM:
            out.append(("lm", submit("lm", prompts[i],
                                     max_new_tokens=LM_GEN)))
    return out


def _lat_stats(lats: dict) -> dict:
    pct = lambda xs, p: round(float(np.percentile(np.asarray(xs), p)) * 1e3, 2)
    return {w: {"p50_ms": pct(ls, 50), "p99_ms": pct(ls, 99)}
            for w, ls in lats.items()}


def bench() -> dict:
    (nspec, nq, nkeys), (lspec, lq), (mcfg, params, prompts) = _problems()
    total = R_NVSA + R_LVRF + R_LM

    # --- sync baseline: one engine fully drained after another ------------
    engines = _make_engines(nspec, mcfg, params, lspec)
    _warm(engines, nq, nkeys, lq, prompts)
    t0 = time.perf_counter()
    sync_lat: dict = {w: [] for w in engines}
    handles = _submit_all(lambda w, p, **kw: engines[w].submit(p, **kw),
                          nq, nkeys, lq, prompts)
    for name in ("nvsa", "lvrf", "lm"):
        for req in engines[name].drain():
            # per-request latency from the engine's own accounting: submits
            # all happened at ~t0, so a request's wait behind every EARLIER
            # engine's full drain is included — the sync pattern's real cost
            sync_lat[name].append(req.latency_s)
    t_sync = time.perf_counter() - t0
    del handles

    # --- runtime: same engines fresh, one async frontend ------------------
    engines = _make_engines(nspec, mcfg, params, lspec)
    _warm(engines, nq, nkeys, lq, prompts)
    runtime = rt.Runtime()
    for name, e in engines.items():
        runtime.register(name, e)
    with runtime:
        t0 = time.perf_counter()
        handles = _submit_all(runtime.submit, nq, nkeys, lq, prompts)
        rt_lat: dict = {w: [] for w in engines}
        for wname, gid in handles:
            req = runtime.result(gid, timeout=600)
            rt_lat[wname].append(req.latency_s)
        t_rt = time.perf_counter() - t0

    return {
        "requests": {"nvsa": R_NVSA, "lvrf": R_LVRF,
                     "lm": f"{R_LM}x{LM_GEN}tok"},
        "sync": {"wall_s": round(t_sync, 4),
                 "requests_per_s": round(total / t_sync, 2),
                 "latency": _lat_stats(sync_lat)},
        "runtime": {"wall_s": round(t_rt, 4),
                    "requests_per_s": round(total / t_rt, 2),
                    "latency": _lat_stats(rt_lat),
                    "sweeps": {n: engines[n].sweeps_total
                               for n in ("nvsa", "lvrf")}},
        "sync_drain_order": ["nvsa", "lvrf", "lm"],  # first is privileged
        "throughput_ratio_runtime_over_sync": round(t_sync / t_rt, 2),
        "p50_ratio_sync_over_runtime": {
            w: round(np.median(sync_lat[w]) / max(np.median(rt_lat[w]), 1e-9),
                     2) for w in sync_lat},
        # the mixed-traffic fairness number: under sync SOME class must queue
        # behind every other engine's full drain; the runtime has no such tail
        "worst_class_p50_ratio_sync_over_runtime": round(
            max(np.median(v) for v in sync_lat.values())
            / max(max(np.median(v) for v in rt_lat.values()), 1e-9), 2),
    }


def run() -> list[dict]:
    b = bench()
    p50 = b["p50_ratio_sync_over_runtime"]
    return [row(
        "runtime_serve",
        f"mixed_async_vs_sync(nvsa={R_NVSA},lvrf={R_LVRF},lm={R_LM})",
        b["runtime"]["wall_s"] * 1e6,
        f"sync_us={b['sync']['wall_s']*1e6:.0f} "
        f"throughput_ratio={b['throughput_ratio_runtime_over_sync']}x "
        f"worst_p50={b['worst_class_p50_ratio_sync_over_runtime']}x "
        f"p50_gain nvsa={p50['nvsa']}x lvrf={p50['lvrf']}x lm={p50['lm']}x")]


def main() -> None:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_runtime.json")
    out = write_bench(
        path, "runtime_serve", bench(),
        workload=("mixed online traffic through one Runtime: "
                  f"{R_NVSA} NVSA factorization tasks (1.4-sigma query "
                  f"noise) + {R_LVRF} LVRF row decodes + {R_LM} LM greedy "
                  f"generations x {LM_GEN} tokens (llama3.2 smoke config), "
                  "vs the same engines drained synchronously one after "
                  "another"),
        timing_mode=("CPU wall clock — NOT TPU-predictive; the p50 ratios "
                     "(no workload queues behind a foreign engine's full "
                     "drain) are the transferable signal"),
        config={"r_nvsa": R_NVSA, "r_lvrf": R_LVRF, "r_lm": R_LM,
                "lm_gen": LM_GEN})
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
