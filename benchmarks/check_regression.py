"""Structural regression gate over BENCH_traffic.json baselines.

CI quality gates on wall-clock collapse on shared runners; the numbers that
ARE stable are the structural counters of the deterministic replay leg
(``benchmarks.traffic.replay_structural``): how many sweeps the factorizer
engines executed, how many psums each sweep costs, whether the fused Pallas
sweep path was taken, how many prefill/decode dispatches the LM served and
how many KV bytes they touched.  Those counters change only when the CODE
changes — scheduler policy, batching, kernel eligibility — which is exactly
the regression class worth gating.

``compare()`` diffs a fresh run's structural section against a committed
baseline envelope under per-counter tolerances: structure-per-unit counters
(``psums_per_sweep``, ``pallas_calls_per_sweep``, ``units_per_step``,
``prefill_dispatches`` — one per request, by construction) must match
exactly; volume counters (``sweeps_total``, ``steps``, ``tokens_total``,
``decode_dispatches``, ``kv_bytes_touched``) get a small relative band so a
benign scheduling tweak doesn't block CI while a 2x blowup still fails.
Wall-clock fields are deliberately never inspected.

``python -m benchmarks.check_regression --baseline BENCH_traffic.json``
re-runs the deterministic leg with the baseline's own recorded config and
exits non-zero on any violation; ``--fresh other.json`` diffs two committed
envelopes instead (no replay — pure file comparison, used by the tests).
"""
from __future__ import annotations

import argparse
import json
import sys

#: counter -> max |fresh - base| / max(|base|, 1) before it's a violation.
#: 0.0 means exact.  Anything absent from this table is reported-only.
DEFAULT_TOLERANCES = {
    "psums_per_sweep": 0.0,
    "pallas_calls_per_sweep": 0.0,
    "units_per_step": 0.0,
    "prefill_dispatches": 0.0,
    "sweeps_total": 0.05,
    "steps": 0.05,
    "tokens_total": 0.05,
    "decode_dispatches": 0.05,
    "kv_bytes_touched": 0.05,
    # fleet-controller decision counters (overload leg, per class): the
    # replay is fully deterministic, so any drift means the admission /
    # preemption / brownout / rebalance policy itself changed — gate exact
    "admitted": 0.0,
    "shed": 0.0,
    "degraded": 0.0,
    "preempted": 0.0,
    "rebalances": 0.0,
    "brownouts": 0.0,
}


def compare(baseline: dict, fresh: dict,
            tolerances: dict | None = None) -> list[str]:
    """Diff two per-engine structural-counter dicts; returns violation
    strings (empty list == gate passes).  Engines or counters present in
    the baseline but missing from the fresh run are violations — a counter
    silently disappearing is itself a structural change."""
    tol = dict(DEFAULT_TOLERANCES)
    if tolerances:
        tol.update(tolerances)
    out = []
    for eng in sorted(baseline):
        if eng not in fresh:
            out.append(f"{eng}: engine missing from fresh run")
            continue
        base_c, fresh_c = baseline[eng], fresh[eng]
        for key in sorted(base_c):
            if key not in tol:
                continue  # reported-only counter
            if key not in fresh_c:
                out.append(f"{eng}.{key}: missing from fresh run "
                           f"(baseline {base_c[key]})")
                continue
            b, f = base_c[key], fresh_c[key]
            lim = tol[key]
            drift = abs(f - b) / max(abs(b), 1)
            if drift > lim:
                out.append(
                    f"{eng}.{key}: {b} -> {f} "
                    f"(drift {drift:.3f} > tol {lim})")
    return out


def _load(path: str) -> dict:
    with open(path) as fp:
        env = json.load(fp)
    sv = env.get("schema_version")
    if sv != 1:
        raise SystemExit(f"{path}: unsupported bench schema_version {sv!r}")
    if "structural" not in env.get("result", {}):
        raise SystemExit(f"{path}: no result.structural section "
                         f"(benchmark={env.get('benchmark')!r})")
    return env


def _fresh_structural(cfg: dict) -> dict:
    """Re-run the deterministic legs with the baseline's recorded config
    (including the fleet-controlled overload leg when the baseline
    recorded one — older envelopes without it replay as before)."""
    from benchmarks import traffic

    return traffic.structural_suite(cfg)["structural"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_traffic.json")
    ap.add_argument("--fresh", default=None,
                    help="diff this envelope instead of re-running the "
                         "deterministic replay leg")
    ap.add_argument("--tolerance", action="append", default=[],
                    metavar="COUNTER=REL",
                    help="override one counter's relative tolerance")
    args = ap.parse_args(argv)

    base_env = _load(args.baseline)
    overrides = {}
    for spec in args.tolerance:
        key, _, val = spec.partition("=")
        overrides[key] = float(val)

    if args.fresh is not None:
        fresh_env = _load(args.fresh)
        if fresh_env.get("config") != base_env.get("config"):
            print(f"config mismatch: baseline {base_env.get('config')} "
                  f"vs fresh {fresh_env.get('config')}")
            return 1
        fresh = fresh_env["result"]["structural"]
    else:
        fresh = _fresh_structural(base_env["config"])

    violations = compare(base_env["result"]["structural"], fresh, overrides)
    if violations:
        print(f"REGRESSION: {len(violations)} structural counter(s) "
              f"drifted vs {args.baseline}")
        for v in violations:
            print(f"  {v}")
        return 1
    n = sum(len([k for k in c if k in DEFAULT_TOLERANCES])
            for c in base_env["result"]["structural"].values())
    print(f"ok: {n} gated structural counters within tolerance "
          f"of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
