"""Trace-driven load harness: seeded arrival traces over mixed traffic.

ROADMAP item 4's missing piece: replay bursty/diurnal/adversarial arrival
patterns over mixed nvsa+lvrf+lm traffic and report per-class SLO
attainment as the system's steady-state contract.  The harness runs the
same trace through TWO legs with different guarantees:

* **structural leg** (``replay_structural``) — a single-threaded
  discrete-event replay: arrivals land on a virtual clock, one
  deterministic SFQ rule (min virtual time, cost-weighted advance — the
  same math as ``Runtime._pick``) chooses which engine steps next.  No
  threads, no wall-clock in the loop, per-request pinned PRNG keys —
  so the submit sequence, the results (bit-equal), and the structural
  counters (sweeps, dispatches, KV bytes) are exactly reproducible.
  These counters are what ``check_regression.py`` gates.

* **runtime leg** (``replay_runtime``) — the real threaded
  :class:`repro.runtime.Runtime` under a live recorder: submissions
  sleep until each arrival's (scaled) trace time, classes and SLO
  targets flow through ``submit(class_=...)``, optionally one engine
  runs under a seeded :class:`ChaosEngine`.  This leg produces the
  per-class attainment snapshot, the span-derived attribution report,
  and the Chrome trace.  Its wall-clock numbers are REPORTED, never
  gated (CPU/interpret-mode timing is not predictive).

A third, fleet-controlled structural leg replays an ``overload`` trace
(sustained arrivals above capacity, mixed priority classes) under
:func:`overload_fleet` policy, so the controller's admission / preemption /
brownout / rebalance decision counters are deterministic and gated too.

``python -m benchmarks.traffic`` writes the unified BENCH envelope
(structural counters + SLO attainment + attribution summary) and the
Chrome trace; ``--events/--seed/--kind`` scale it for CI smoke runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench
from repro import engine as eng_mod
from repro import obs
from repro import runtime as rt
from repro.configs.registry import ARCHS
from repro.core import factorizer as fz
from repro.models import lvrf, nvsa
from repro.nn import transformer as T
from repro.runtime import faults as flt
from repro.runtime.protocol import step_cost_seconds

TRACE_KINDS = ("bursty", "diurnal", "adversarial", "overload")

#: Engine mix weights: nvsa factorizations and lvrf row decodes dominate,
#: LM generations are the heavy minority class (one costs many steps).
DEFAULT_MIX = (("nvsa", 3), ("lvrf", 4), ("lm", 1))

LM_GEN = 8  # tokens generated per LM request
_KIND_SALT = {k: i + 1 for i, k in enumerate(TRACE_KINDS)}

#: Priority-class mix for ``overload`` traces: a small latency-sensitive
#: minority swamped by best-effort bulk — the shape fleet admission
#: control exists for.
OVERLOAD_CLASSES = (("interactive", 1), ("best_effort", 3))

#: Engine mix for ``overload`` traces: weighted toward the multi-step LM
#: engine so live rows actually span control ticks — the precondition for
#: priority preemption (single-step symbolic requests never hold a slot
#: long enough to be worth preempting).
OVERLOAD_MIX = (("nvsa", 2), ("lvrf", 3), ("lm", 3))


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One trace event: at trace-time ``t`` submit payload ``idx`` of
    ``engine``'s pool.  ``cls`` is the priority class (empty for the
    classless trace kinds — replays then fall back to the engine name)."""

    t: float
    engine: str
    idx: int
    cls: str = ""


# -- trace generation ------------------------------------------------------


def make_trace(kind: str, *, seed: int = 0, events: int = 48,
               duration_s: float = 1.0, mix=None) -> list[Arrival]:
    """Seeded arrival trace of `events` arrivals over ``[0, duration_s)``.

    * ``bursty`` — Poisson-ish bursts separated by idle gaps (the paper's
      irregular-workload argument at the traffic level);
    * ``diurnal`` — sinusoidally modulated rate (a day compressed into the
      trace window), sampled by thinning;
    * ``adversarial`` — a steady trickle plus one synchronized spike of
      the heaviest engine's requests at mid-trace (worst case for a
      virtual-time scheduler: one class tries to monopolize the stepper);
    * ``overload`` — sustained arrivals at a rate the fleet cannot keep up
      with, tagged with mixed priority classes (``OVERLOAD_CLASSES``): the
      input the fleet controller's admission/preemption/brownout policies
      are exercised (and gated) against.
    """
    if kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {kind!r}; one of {TRACE_KINDS}")
    if mix is None:
        mix = OVERLOAD_MIX if kind == "overload" else DEFAULT_MIX
    rng = np.random.default_rng([seed, _KIND_SALT[kind]])
    names = [n for n, _ in mix]
    w = np.asarray([float(x) for _, x in mix])
    w = w / w.sum()

    if kind == "bursty":
        times = []
        t = 0.0
        while len(times) < events:
            burst = int(rng.integers(3, 9))
            for _ in range(burst):
                if len(times) >= events:
                    break
                t += float(rng.exponential(duration_s / (events * 6)))
                times.append(t)
            t += float(rng.exponential(duration_s / 6))  # off period
        times = np.asarray(times)
        times = times / times.max() * duration_s * 0.95
    elif kind == "diurnal":
        # thinning against rate(t) = 1 + 0.9 sin(2 pi t / duration)
        times = []
        while len(times) < events:
            cand = float(rng.uniform(0, duration_s))
            rate = 1.0 + 0.9 * np.sin(2 * np.pi * cand / duration_s)
            if rng.uniform(0, 1.9) < rate:
                times.append(cand)
        times = np.sort(np.asarray(times))
    elif kind == "adversarial":
        n_spike = events // 2
        trickle = np.sort(rng.uniform(0, duration_s, events - n_spike))
        spike = np.full(n_spike, duration_s * 0.5)
        times = np.sort(np.concatenate([trickle, spike]))
    else:  # overload: sustained pressure, no idle gaps to drain into
        gaps = rng.exponential(duration_s / events, size=events)
        times = np.cumsum(gaps)
        times = times / times.max() * duration_s * 0.95

    engines = [names[i] for i in rng.choice(len(names), size=events, p=w)]
    if kind == "adversarial":
        # the spike is all one (heaviest) class: everything landing at the
        # spike instant targets the LAST engine in the mix (lm by default)
        heavy = names[-1]
        engines = [heavy if abs(t - duration_s * 0.5) < 1e-12 else e
                   for t, e in zip(times, engines)]
    classes = [""] * events
    if kind == "overload":
        # class draw happens AFTER the engine draw and only on this branch,
        # so the older kinds' rng streams (and digests) are untouched
        cnames = [c for c, _ in OVERLOAD_CLASSES]
        cw = np.asarray([float(x) for _, x in OVERLOAD_CLASSES])
        classes = [cnames[j] for j in
                   rng.choice(len(cnames), size=events, p=cw / cw.sum())]
    counts: dict[str, int] = {n: 0 for n in names}
    out = []
    for t, e, c in zip(times, engines, classes):
        out.append(Arrival(float(t), e, counts[e], c))
        counts[e] += 1
    return out


# -- shared problem pools / engines ----------------------------------------


def build_problems(seed: int = 0, *, n_nvsa: int = 24, n_lvrf: int = 32,
                   n_lm: int = 12):
    """Deterministic payload pools; trace ``idx`` indexes them modulo size.
    Per-request pinned PRNG keys make replays bit-equal regardless of
    fill/burst interleave."""
    ncfg = nvsa.NVSAConfig()
    cbs, mask = nvsa.make_codebooks(jax.random.PRNGKey(0), ncfg)
    k_idx, k_noise, k_fact = jax.random.split(jax.random.PRNGKey(seed), 3)
    idxs = jnp.stack([jax.random.randint(jax.random.fold_in(k_idx, a),
                                         (n_nvsa,), 0, n)
                      for a, n in enumerate(nvsa.ATTR_SIZES)], axis=-1)
    nq = fz.bind_combo(cbs, idxs, ncfg.factorizer.vsa)
    nq = nq + 1.4 * jnp.std(nq) * jax.random.normal(k_noise, nq.shape)
    nkeys = jax.random.split(k_fact, n_nvsa)
    nspec = eng_mod.ServeSpec("bench_nvsa_queries", cbs, ncfg.factorizer,
                              mask)

    lspec = eng_mod.registry.build("lvrf_rows", jax.random.PRNGKey(0))
    lcfg = lvrf.LVRFConfig()
    atoms = lvrf.init_atoms(jax.random.split(jax.random.PRNGKey(0))[0], lcfg)
    vals = jnp.asarray(np.random.default_rng(seed).integers(
        0, lcfg.n_values, (n_lvrf, 3)))
    lq = lvrf.encode_row(atoms, vals, lcfg)
    lkeys = jax.random.split(jax.random.PRNGKey(seed + 1), n_lvrf)

    mcfg = ARCHS["llama3.2-3b"].smoke()
    params, _ = T.init(jax.random.PRNGKey(0), mcfg)
    prompts = [jax.random.randint(jax.random.PRNGKey(100 + i), (6,), 0,
                                  mcfg.vocab) for i in range(n_lm)]
    return {"nvsa": (nspec, nq, nkeys), "lvrf": (lspec, lq, lkeys),
            "lm": (mcfg, params, prompts)}


def build_engines(problems, engines=("nvsa", "lvrf", "lm")) -> dict:
    out: dict = {}
    if "nvsa" in engines:
        out["nvsa"] = eng_mod.Engine(problems["nvsa"][0], slots=4,
                                     sweeps_per_step=4)
    if "lvrf" in engines:
        out["lvrf"] = eng_mod.Engine(problems["lvrf"][0], slots=4)
    if "lm" in engines:
        mcfg, params, _ = problems["lm"]
        out["lm"] = rt.LMEngine(mcfg, params, slots=2,
                                max_len=6 + LM_GEN + 1, decode_per_step=2)
    return out


def _warm(engines, problems) -> None:
    """Compile each engine's programs outside the measured region, then
    reset the serving counters so structural baselines exclude warmup."""
    if "nvsa" in engines:
        _, nq, nkeys = problems["nvsa"]
        engines["nvsa"].submit(nq[0], keys=nkeys[:1])
    if "lvrf" in engines:
        _, lq, lkeys = problems["lvrf"]
        engines["lvrf"].submit(lq[0], keys=lkeys[:1])
    if "lm" in engines:
        _, _, prompts = problems["lm"]
        engines["lm"].submit(prompts[0], max_new_tokens=2)
    for name, e in engines.items():
        e.drain()
        e.completed.clear()
        if name == "lm":
            e.steps_total = e.tokens_total = 0
            e.serve.prefill_dispatches = e.serve.decode_dispatches = 0
            e.serve.kv_bytes_touched = 0
        else:
            e.sweeps_total = e.steps_total = 0


def _submit(engines, problems, ev: Arrival):
    if ev.engine == "nvsa":
        _, nq, nkeys = problems["nvsa"]
        i = ev.idx % nq.shape[0]
        return nq[i], {"keys": nkeys[i:i + 1]}
    if ev.engine == "lvrf":
        _, lq, lkeys = problems["lvrf"]
        i = ev.idx % lq.shape[0]
        return lq[i], {"keys": lkeys[i:i + 1]}
    _, _, prompts = problems["lm"]
    return prompts[ev.idx % len(prompts)], {"max_new_tokens": LM_GEN}


def _result_digest(results: list) -> str:
    """Stable content hash over the ordered result payloads — the
    determinism probe (same seed -> bit-equal results)."""
    h = hashlib.sha256()
    for engine, idx, res in results:
        h.update(f"{engine}:{idx}".encode())
        # results are pytrees (dicts, namedtuples, token lists): hash the
        # ordered leaves so any payload shape digests the same way
        for leaf in jax.tree_util.tree_leaves(res):
            h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


# -- leg 1: deterministic structural replay --------------------------------


def replay_structural(trace, problems, *, steps_per_s: float | None = None,
                      engines=None, fleet=None) -> dict:
    """Single-threaded discrete-event replay of `trace`.

    Virtual time advances by ``1 / steps_per_s`` per engine step (service
    capacity) and jumps to the next arrival when every engine is idle;
    engine choice is the Runtime's SFQ rule (min virtual time, virtual
    time advanced by modeled step cost / backlog, start-time clamped).
    Everything is deterministic: no threads, no wall clock, pinned keys.

    ``fleet`` (a :class:`repro.runtime.FleetPolicy` or bound controller)
    puts the same :class:`~repro.runtime.FleetController` the threaded
    Runtime uses in the loop: every arrival goes through ``admit`` (shed
    arrivals never reach an engine; degraded ones get trimmed budgets;
    admitted ones carry their class priority), and ``control`` runs on the
    virtual clock after every step — so admission/preemption/brownout/
    rebalance decision counters are exactly reproducible and regression-
    gateable alongside the engine counters.
    """
    kinds = engines if engines is not None else \
        tuple(dict.fromkeys(ev.engine for ev in trace))
    engs = build_engines(problems, kinds)
    _warm(engs, problems)
    if steps_per_s is None:
        dur = max((ev.t for ev in trace), default=0.0) or 1.0
        steps_per_s = 3.0 * len(trace) / dur
    ctrl = None
    cls_of: dict[tuple[str, int], str] = {}
    if fleet is not None:
        ctrl = fleet if isinstance(fleet, rt.FleetController) \
            else rt.FleetController(fleet)
        units = {n: int(getattr(e, "sweeps_per_step", 0)
                        or getattr(e, "decode_per_step", 0) or 1)
                 for n, e in engs.items()}
        # one engine step == 1/steps_per_s virtual seconds, so the modeled
        # per-unit cost is that, split across the step's units
        ctrl.bind(engs,
                  unit_s_fn=lambda n: (1.0 / steps_per_s) / units[n],
                  class_of=lambda n, rid: cls_of.get((n, rid)))
    vt = {n: 0.0 for n in engs}
    vclock = 0.0
    was_busy: set = set()
    now = 0.0
    i = 0
    submit_seq: list[tuple[str, int]] = []
    shed_seq: list[tuple[str, int]] = []
    submitted: dict[str, dict] = {n: {} for n in engs}  # local id -> idx
    results: list = []
    steps = 0
    while i < len(trace) or any(e.in_flight for e in engs.values()):
        while i < len(trace) and trace[i].t <= now:
            ev = trace[i]
            i += 1
            payload, kw = _submit(engs, problems, ev)
            if ctrl is not None:
                cls = ev.cls or ev.engine
                decision = ctrl.admit(ev.engine, cls, now=now)
                if decision.action == "shed":
                    shed_seq.append((ev.engine, ev.idx))
                    continue
                kw = decision.apply(kw)
                kw["priority"] = decision.priority
            rid = engs[ev.engine].submit(payload, **kw)
            if ctrl is not None:
                cls_of[(ev.engine, rid)] = cls
            submitted[ev.engine][rid] = ev.idx
            submit_seq.append((ev.engine, ev.idx))
        busy = [n for n, e in engs.items() if e.in_flight]
        if not busy:
            if i < len(trace):
                now = trace[i].t  # idle fleet: jump to the next arrival
                was_busy.clear()
                continue
            break
        # SFQ pick — the same math as Runtime._pick, minus the threads
        for n in busy:
            if n not in was_busy:
                vt[n] = max(vt[n], vclock)
        was_busy = set(busy)
        pick = min(busy, key=lambda n: vt[n])
        vclock = vt[pick]
        finished = engs[pick].step()
        steps += 1
        backlog = engs[pick].in_flight + len(finished)
        vt[pick] += step_cost_seconds(engs[pick]) / max(1, backlog)
        now += 1.0 / steps_per_s
        if ctrl is not None:
            ctrl.control(now=now)
        for req in finished:
            idx = submitted[pick].pop(req.id)
            res = req.result if not hasattr(req, "tokens") else req.tokens
            results.append((pick, idx, res))
    counters = structural_counters(engs)
    out = {"submit_seq": submit_seq, "results": results,
           "digest": _result_digest(results), "steps": steps,
           "steps_per_s": steps_per_s, "structural": counters}
    if ctrl is not None:
        counters.update(ctrl.structural_counters())
        out["shed_seq"] = shed_seq
        out["fleet"] = ctrl.snapshot()
    return out


def structural_counters(engines: dict) -> dict:
    """The gated (deterministic, transferable) counters per engine."""
    out = {}
    for name, e in engines.items():
        if hasattr(e, "serve"):  # LMEngine
            out[name] = {
                "steps": e.steps_total,
                "tokens_total": e.tokens_total,
                "prefill_dispatches": e.serve.prefill_dispatches,
                "decode_dispatches": e.serve.decode_dispatches,
                "kv_bytes_touched": e.serve.kv_bytes_touched,
                "units_per_step": e.decode_per_step,
            }
        else:
            out[name] = {
                "steps": e.steps_total,
                "sweeps_total": e.sweeps_total,
                "units_per_step": e.sweeps_per_step,
                "psums_per_sweep": e._psums_per_sweep(),
                "pallas_calls_per_sweep":
                    1 if (e.spec.cfg is not None
                          and fz.fused_sweep_eligible(e.spec.cfg)) else 0,
            }
    return out


def overload_fleet(steps_per_s: float) -> rt.FleetPolicy:
    """The fleet policy the overload leg (and the CI overload scenario)
    runs under.  Thresholds are denominated in virtual step times
    (``1 / steps_per_s``) so the same policy works at any replay speed:
    best-effort work degrades past ~2 queued steps of estimated wait, is
    shed past ~4, and a sustained ~2.5-step backlog browns the fleet out;
    interactive work is never shed and never trimmed, and preempts
    best-effort rows out of live slots."""
    step_v = 1.0 / steps_per_s
    return rt.FleetPolicy(
        classes=(
            rt.PriorityClass("interactive", priority=0),
            rt.PriorityClass("best_effort", priority=3,
                             admit_wait_s=4 * step_v,
                             degrade_wait_s=2 * step_v,
                             preemptible=True, degradable=True),
        ),
        default_class="best_effort",
        max_preempt_per_tick=2,
        rebalance_every=8, rebalance_step=1, rebalance_ratio=1.5,
        min_slots=2,
        brownout=rt.BrownoutPolicy(enter_wait_s=2.5 * step_v,
                                   exit_wait_s=1 * step_v,
                                   enter_ticks=2, exit_ticks=2,
                                   lm_token_cap=4),
    )


def structural_suite(cfg: dict) -> dict:
    """Every deterministic counter the regression gate inspects, from one
    recorded config: the base trace replay plus — when the config carries
    an ``overload`` sub-dict — the fleet-controlled overload leg, whose
    per-engine counters and per-class fleet decision counters are merged
    in under ``overload_*`` keys.  Shared by ``bench()`` and
    ``check_regression._fresh_structural`` so the gate re-runs exactly
    what the baseline recorded."""
    problems = build_problems(cfg["seed"])
    trace = make_trace(cfg["kind"], seed=cfg["seed"], events=cfg["events"],
                       duration_s=cfg["duration_s"])
    base = replay_structural(trace, problems)
    out = {"structural": dict(base["structural"]), "steps": base["steps"],
           "steps_per_s": base["steps_per_s"], "digest": base["digest"]}
    ov = cfg.get("overload")
    if ov:
        otrace = make_trace("overload", seed=ov["seed"],
                            events=ov["events"],
                            duration_s=ov["duration_s"])
        sps = float(ov["steps_per_s"])
        res = replay_structural(otrace, problems, steps_per_s=sps,
                                fleet=overload_fleet(sps))
        for name, ctrs in res["structural"].items():
            out["structural"][f"overload_{name}"] = ctrs
        out["overload_digest"] = res["digest"]
        out["overload_fleet"] = res["fleet"]
    return out


# -- leg 2: runtime replay (SLO + attribution + chrome trace) --------------

DEFAULT_SLO = {
    "nvsa": obs.SLOTarget(20.0, percentile=95),
    "lvrf": obs.SLOTarget(20.0, percentile=95),
    "lm": obs.SLOTarget(60.0, percentile=95),
}


def replay_runtime(trace, problems, *, time_scale: float = 1.0,
                   slo=None, chaos_seed: int | None = None,
                   recorder=None) -> dict:
    """Replay `trace` through the real threaded Runtime under a recorder.

    Arrival times are honored (scaled by ``time_scale``) with wall-clock
    sleeps; each request is submitted with ``class_=`` its engine's pool
    name so the SLO tracker and the attribution report see per-class
    traffic.  ``chaos_seed`` wraps the lvrf engine in a seeded
    :class:`ChaosEngine` (one injected fault) so the report has a
    quarantine/replay episode to attribute.
    """
    kinds = tuple(dict.fromkeys(ev.engine for ev in trace))
    engs = build_engines(problems, kinds)
    _warm(engs, problems)
    rec = recorder if recorder is not None else obs.Recorder()
    if chaos_seed is not None and "lvrf" in engs:
        engs["lvrf"] = flt.ChaosEngine(engs["lvrf"], flt.FaultPlan(
            seed=chaos_seed, step_error_rate=0.4, max_faults=1))
    runtime = rt.Runtime(obs=rec, slo=dict(slo if slo is not None
                                           else DEFAULT_SLO),
                         failure=rt.FailurePolicy(
                             max_restarts=8, backoff_initial_s=0.01,
                             backoff_max_s=0.05))
    for name, e in engs.items():
        runtime.register(name, e)
    t_wall0 = time.perf_counter()
    with runtime:
        start = time.perf_counter()
        gids = []
        for ev in trace:
            lag = ev.t * time_scale - (time.perf_counter() - start)
            if lag > 0:
                time.sleep(lag)
            payload, kw = _submit(engs, problems, ev)
            gids.append(runtime.submit(ev.engine, payload,
                                       class_=ev.engine, **kw))
        runtime.drain(timeout=600, return_exceptions=True)
        slo_snap = runtime.stats()["slo"]
    wall_s = time.perf_counter() - t_wall0
    report = obs.attribution(rec)
    return {"slo": slo_snap, "report": report, "recorder": rec,
            "wall_s": wall_s, "gids": gids}


# -- CLI -------------------------------------------------------------------


def _slo_summary(slo: dict) -> dict:
    keep = ("submitted", "completed", "deadline_missed", "shed", "failed",
            "latency_p50_s", "latency_p95_s", "latency_p99_s", "target_s",
            "attainment", "attained", "deadline_miss_rate", "shed_rate")
    return {c: {k: row.get(k) for k in keep} for c, row in slo.items()}


def _attribution_summary(report: dict) -> dict:
    return {
        "coverage": report["coverage"],
        "engines": {e: {"steps": st["steps"],
                        "phase_s": {k: round(v, 6)
                                    for k, v in st["phase_s"].items()},
                        "span_drift_ratio": st["span_drift_ratio"]}
                    for e, st in report["engines"].items()},
        "classes": report["classes"],
    }


def overload_config(seed: int, events: int, duration_s: float) -> dict:
    """The overload leg's recorded replay config.  ``steps_per_s`` is
    pinned at one virtual step per FOUR mean inter-arrival gaps — far
    below what the batched multi-step requests need, i.e. sustained
    overload — and written into the envelope so the gate replays at the
    same speed."""
    return {"seed": seed, "events": events, "duration_s": duration_s,
            "steps_per_s": round(events / duration_s / 4.0, 6)}


def bench(kind: str = "bursty", *, seed: int = 0, events: int = 48,
          duration_s: float = 1.0, time_scale: float = 1.0,
          chaos_seed: int | None = 1, trace_out: str | None = None) -> dict:
    trace = make_trace(kind, seed=seed, events=events, duration_s=duration_s)
    problems = build_problems(seed)
    suite = structural_suite({
        "kind": kind, "seed": seed, "events": events,
        "duration_s": duration_s,
        "overload": overload_config(seed, events, duration_s)})
    live = replay_runtime(trace, problems, time_scale=time_scale,
                          chaos_seed=chaos_seed)
    if trace_out:
        live["recorder"].write_chrome_trace(trace_out)
    per_engine: dict[str, int] = {}
    for ev in trace:
        per_engine[ev.engine] = per_engine.get(ev.engine, 0) + 1
    return {
        "trace": {"kind": kind, "seed": seed, "events": events,
                  "duration_s": duration_s, "per_engine": per_engine},
        "structural": suite["structural"],
        "structural_steps": suite["steps"],
        "steps_per_s": suite["steps_per_s"],
        "digest": suite["digest"],
        "overload": {"digest": suite["overload_digest"],
                     "fleet": suite["overload_fleet"]},
        "slo": _slo_summary(live["slo"]),
        "attribution": _attribution_summary(live["report"]),
        "runtime_wall_s": round(live["wall_s"], 3),
        "chaos": {"seed": chaos_seed,
                  "enabled": chaos_seed is not None},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kind", default="bursty", choices=TRACE_KINDS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", type=int, default=48)
    ap.add_argument("--duration-s", type=float, default=1.0)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--no-chaos", action="store_true")
    ap.add_argument("--out", default="BENCH_traffic.json")
    ap.add_argument("--trace-out", default=None,
                    help="also write the Chrome trace JSON here")
    args = ap.parse_args(argv)
    result = bench(args.kind, seed=args.seed, events=args.events,
                   duration_s=args.duration_s, time_scale=args.time_scale,
                   chaos_seed=None if args.no_chaos else 1,
                   trace_out=args.trace_out)
    env = write_bench(
        args.out, "traffic", result,
        workload=(f"{args.events} mixed nvsa+lvrf+lm arrivals, "
                  f"{args.kind} trace (seed {args.seed}) — deterministic "
                  "structural replay + live Runtime SLO replay"),
        timing_mode=("CPU wall clock for the runtime leg — NOT "
                     "TPU-predictive; the structural counters from the "
                     "deterministic leg are the gated signal"),
        config={"kind": args.kind, "seed": args.seed, "events": args.events,
                "duration_s": args.duration_s,
                "overload": overload_config(args.seed, args.events,
                                            args.duration_s),
                "chaos": not args.no_chaos})
    print(json.dumps({"slo": env["result"]["slo"],
                      "coverage": env["result"]["attribution"]["coverage"],
                      "digest": env["result"]["digest"],
                      "overload_fleet": env["result"]["overload"]["fleet"]},
                     indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
