"""Paged-KV vs contiguous LM serving: structural cost of the KV cache.

The same mixed-length greedy request set runs through two
:class:`repro.runtime.LMEngine` instances — one on the contiguous
``[layers, slots, max_len]`` cache (dense einsum reads the FULL row every
token; one prefill dispatch PER TOKEN), one on the paged block-table pool
(flash-decode gathers ``ceil(len/block)`` KV blocks; chunked prefill
dispatches ``ceil(tokens/chunk)`` times).

On one host CPU the interpret-mode Pallas kernel cannot win wall clock, so
the numbers that transfer are STRUCTURAL and exact:

  * ``prefill_dispatches``   — kernel launches to admit the request set;
  * ``kv_bytes_per_decode``  — KV bytes gathered per decode dispatch
    (counted by the engine from live lengths, not timed);
  * ``modeled_step_s``       — the adSCH cost model's decode-step time,
    which now prices the KV read term (``lm_decode``'s ``kv_block``);

plus one sanity gate: both engines must emit IDENTICAL greedy token
streams.  ``python -m benchmarks.lm_serve`` writes BENCH_lm.json at the
repo root; ``run()`` feeds the shared bench.json harness.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import row, write_bench
from repro import runtime as rt
from repro.configs.registry import ARCHS
from repro.lm.paging import PagedConfig
from repro.nn import transformer as T

SLOTS = 4
MAX_LEN = 48
GEN = 12
PROMPT_LENS = (3, 7, 12, 17, 24, 9)  # off/at block boundaries for bs=8
BLOCK, CHUNK = 8, 8


def _requests(cfg):
    return [jax.random.randint(jax.random.PRNGKey(i + 1), (n,), 0, cfg.vocab)
            for i, n in enumerate(PROMPT_LENS)]


def _serve(eng, prompts) -> tuple[dict, float, dict]:
    """Push the request set through one engine; returns (streams, wall,
    stats)."""
    # warm the compile caches outside the timed region
    wid = eng.submit(prompts[0], max_new_tokens=2)
    eng.drain()
    eng.serve.prefill_dispatches = eng.serve.decode_dispatches = 0
    eng.serve.kv_bytes_touched = 0
    del wid
    t0 = time.perf_counter()
    ids = [eng.submit(p, max_new_tokens=GEN) for p in prompts]
    done = {r.id: r.tokens for r in eng.drain()}
    wall = time.perf_counter() - t0
    return {i: done[rid] for i, rid in enumerate(ids)}, wall, eng.stats()


def bench() -> dict:
    cfg = ARCHS["llama3.2-3b"].smoke()
    params, _ = T.init(jax.random.PRNGKey(0), cfg)
    prompts = _requests(cfg)

    cont = rt.LMEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                       decode_per_step=2)
    c_streams, c_wall, c_stats = _serve(cont, prompts)

    paged = rt.LMEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                        decode_per_step=2,
                        paged=PagedConfig(block_size=BLOCK,
                                          prefill_chunk=CHUNK))
    p_streams, p_wall, p_stats = _serve(paged, prompts)

    if p_streams != c_streams:
        raise AssertionError("paged and contiguous greedy streams diverged")

    def per_decode(stats):
        return stats["kv_bytes_touched"] / max(stats["decode_dispatches"], 1)

    c_kv, p_kv = per_decode(c_stats), per_decode(p_stats)
    return {
        "streams_equal": True,
        "contiguous": {
            "wall_s": round(c_wall, 4),
            "prefill_dispatches": c_stats["prefill_dispatches"],
            "decode_dispatches": c_stats["decode_dispatches"],
            "kv_bytes_per_decode": int(c_kv),
            "modeled_step_s": cont._step_cost,
        },
        "paged": {
            "wall_s": round(p_wall, 4),
            "prefill_dispatches": p_stats["prefill_dispatches"],
            "decode_dispatches": p_stats["decode_dispatches"],
            "kv_bytes_per_decode": int(p_kv),
            "modeled_step_s": paged._step_cost,
        },
        "prefill_dispatch_ratio": round(
            c_stats["prefill_dispatches"]
            / max(p_stats["prefill_dispatches"], 1), 2),
        "kv_bytes_per_decode_ratio": round(c_kv / max(p_kv, 1), 2),
        "modeled_step_ratio": round(
            cont._step_cost / max(paged._step_cost, 1e-12), 2),
    }


def run() -> list[dict]:
    b = bench()
    return [row(
        "lm_serve",
        f"paged_vs_contiguous(slots={SLOTS},max_len={MAX_LEN},"
        f"block={BLOCK},gen={GEN})",
        b["paged"]["wall_s"] * 1e6,
        f"streams_equal={b['streams_equal']} "
        f"prefill_dispatches={b['paged']['prefill_dispatches']}"
        f"/{b['contiguous']['prefill_dispatches']} "
        f"kv_bytes_per_decode_ratio={b['kv_bytes_per_decode_ratio']}x "
        f"modeled_step_ratio={b['modeled_step_ratio']}x")]


def main() -> None:
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_lm.json")
    out = write_bench(
        path, "lm_serve", bench(),
        workload=(f"{len(PROMPT_LENS)} greedy LM requests (prompts "
                  f"{list(PROMPT_LENS)} tokens, {GEN} generated each) on "
                  f"the llama3.2 smoke config, {SLOTS} slots, "
                  f"max_len={MAX_LEN}: contiguous KV cache vs paged "
                  f"block-table pool (block={BLOCK}, "
                  f"prefill_chunk={CHUNK})"),
        timing_mode=("CPU wall clock with the Pallas flash-decode kernel "
                     "in interpret mode — NOT TPU-predictive; the "
                     "dispatch counts, KV bytes per decode step and "
                     "modeled adSCH step costs are the transferable "
                     "signal"),
        config={"prompt_lens": list(PROMPT_LENS), "gen": GEN,
                "slots": SLOTS, "max_len": MAX_LEN, "block": BLOCK,
                "prefill_chunk": CHUNK})
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
